"""Spot-market churn benchmark (DESIGN.md §16).

``--mode race`` (default) replays the SAME deterministic spot-market trace
(>= 32 workers across 4 price zones with different core counts) through two
arms on ``SimBackend``: dynamic variable batching (controller + cost-aware
reallocation after every churn step) versus the paper's static
``flops_proportional_allocation`` baseline (open-loop split, no
reallocation).  Preemption storms, rejoins and degrading workers hit both
arms identically; the dynamic arm re-apportions the invariant global batch
around them.  With ``--steps`` >= 30 the bench ASSERTS the dynamic arm
reaches the static arm's final loss in less simulated time.

``--mode storm`` replays a mass preemption storm (>= 50% of workers
cycled) on the 8-fake-device debug mesh: Σb_k conserved through every
membership replan, per-worker recompiles within the DESIGN.md §11 ladder
bound, and a mid-storm ``Session.save`` — taken with a preemption landing
between the save and the next round — restores bit-identically.

``--mode chaos`` runs the seeded fault plan (preempt-during-checkpoint,
preempt-during-resize, straggler-during-GNS-cooldown) twice on the sim
backend and ASSERTS the injection log and training history replay
bit-identically.

Prints ``name,value,derived`` CSV like the other drivers.

    PYTHONPATH=src python benchmarks/churn_bench.py [--steps 40]
    PYTHONPATH=src python benchmarks/churn_bench.py --mode storm
    PYTHONPATH=src python benchmarks/churn_bench.py --mode chaos

The CI smoke job runs ``--steps 3`` per mode (the race win assertion is
informational below 30 steps; the storm/chaos assertions are structural
and stay armed).  See ``benchmarks/README.md`` for the row guide.
"""

from __future__ import annotations

import argparse
import math
import os
import sys
import tempfile

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from backend_bench import _force_cpu_devices  # noqa: E402

_ROWS: list = []

STORM_SEED = 6  # 4 workers / 2 zones on the mesh: dense preempt/rejoin mix


def _emit(name, value, derived) -> None:
    _ROWS.append((name, float(value), derived))
    print(f"{name},{float(value):.4g},{derived}")


def _hetero_market(workers: int, *, zones: int, seed: int, horizon: int):
    """>= 32 spot workers across ``zones`` price zones with DIFFERENT core
    counts — so the static flops-proportional split (∝ cores) mismatches
    real throughput (Amdahl is sublinear in cores) even before the storm
    starts, and degrading workers widen the gap."""
    from repro.het.spot import SpotMarket, SpotZone

    per, extra = divmod(workers, zones)
    zs = [
        SpotZone(name=f"z{i}", workers=per + (1 if i < extra else 0),
                 cores=4.0 + 4.0 * i, base_price=1.0 + 0.1 * i,
                 bid=1.5 * (1.0 + 0.1 * i), volatility=0.15,
                 spike_rate=0.04, spike_mag=1.3 + 0.1 * i,
                 degrade_rate=0.01, straggle_rate=0.02)
        for i in range(zones)
    ]
    return SpotMarket(zs, seed=seed, horizon=horizon)


def _race_experiment(market, churn, *, batching: str, args):
    from repro.api import (ClusterSpec, Experiment, SimBackend, TrainConfig,
                           paper_workload)
    from repro.optim import sgd

    cluster = ClusterSpec.explicit(
        market.initial_fleet(), workload="resnet", seed=args.seed,
        backend=SimBackend()).with_churn(churn)
    return Experiment(
        workload=paper_workload("linreg"),
        cluster=cluster,
        optimizer=sgd(args.lr),
        config=TrainConfig(b0=args.b0, microbatch=4, batching=batching,
                           max_steps=args.steps, seed=args.seed),
    )


def _time_to_loss(history, target: float) -> float:
    """First simulated second at which the loss dips to ``target``."""
    for rec in history:
        if rec.loss <= target:
            return rec.sim_time
    return math.inf


def _assert_conserved(history, label: str) -> int:
    total0 = sum(history[0].batches)
    for rec in history:
        assert sum(rec.batches) == total0, (
            f"{label}: step {rec.step} leaked global batch "
            f"({sum(rec.batches)} != {total0})")
    return total0


def run_race(args) -> None:
    from repro.api import compile_churn
    from repro.core import flops_proportional_allocation

    market = _hetero_market(args.workers, zones=4, seed=args.seed,
                            horizon=args.steps)
    trace = market.simulate()
    if args.csv:
        trace.to_csv(args.csv)
    ts = trace.summary()
    _emit("churn/trace/events", len(trace.events),
          f"preempts={ts['preempts']} rejoins={ts['rejoins']} "
          f"degrades={ts['degrades']} straggles={ts['straggles']} "
          f"cycled_fraction={ts['cycled_fraction']:.3g}")
    min_workers = max(2, args.workers // 4)

    # dynamic arm: controller + cost-aware reallocation after churn steps
    dyn_churn = compile_churn(trace, min_workers=min_workers,
                              reallocate=True)
    dyn = _race_experiment(market, dyn_churn, batching="dynamic",
                           args=args).session().run()

    # static arm: flops-proportional open-loop split, same storm, no
    # reallocation events, no controller
    stat_churn = compile_churn(trace, min_workers=min_workers,
                               reallocate=False)
    stat_session = _race_experiment(market, stat_churn, batching="static",
                                    args=args).session()
    peaks = [w.cores * w.flops_ratio for w in stat_session.trainer.sim.workers]
    stat_session.trainer.batches = flops_proportional_allocation(
        peaks, args.b0)
    stat = stat_session.run()

    total_dyn = _assert_conserved(dyn["history"], "dynamic")
    total_stat = _assert_conserved(stat["history"], "static")
    assert total_dyn == total_stat == args.b0 * len(market.initial_fleet())
    _emit("churn/race/workers", len(market.initial_fleet()),
          f"B_global={total_dyn} conserved through "
          f"{dyn_churn.summary().get('RemoveWorker', 0)} preempts + "
          f"{dyn_churn.summary().get('AddWorker', 0)} rejoins on BOTH arms")
    _emit("churn/race/static_final_loss", stat["final_loss"],
          f"sim_time={stat['sim_time']:.4g}s flops_proportional split, "
          f"no reallocation")
    _emit("churn/race/dynamic_final_loss", dyn["final_loss"],
          f"sim_time={dyn['sim_time']:.4g}s "
          f"{dyn['batch_adjustments']} controller updates")

    target = stat["final_loss"] * (1.0 + args.target_slack)
    t_stat = _time_to_loss(stat["history"], target)
    t_dyn = _time_to_loss(dyn["history"], target)
    speedup = t_stat / t_dyn if math.isfinite(t_dyn) and t_dyn > 0 else 0.0
    _emit("churn/race/time_to_target_static", t_stat,
          f"simulated seconds to loss<={target:.4g}")
    _emit("churn/race/time_to_target_dynamic",
          t_dyn if math.isfinite(t_dyn) else -1.0,
          "simulated seconds to the static arm's final loss (-1 = never)")
    _emit("churn/race/sim_speedup", speedup,
          "static/dynamic time-to-target on the same replayed trace "
          "(>1 = dynamic wins)")

    if args.steps < 30:
        _emit("churn/race/asserts", 0,
              "skipped (--steps < 30: no steady state)")
        return
    assert math.isfinite(t_dyn) and t_dyn < t_stat, (
        f"dynamic batching should beat the static flops-proportional split "
        f"to loss<={target:.4g} on the replayed spot trace: "
        f"dynamic={t_dyn:.4g}s static={t_stat:.4g}s")
    _emit("churn/race/asserts", 1,
          f"dynamic beat static to loss<={target:.4g} by {speedup:.3g}x "
          f"under the same preemption storm")


def run_storm(args, mesh) -> None:
    from repro.api import (ClusterSpec, Experiment, MeshBackend, TrainConfig,
                           compile_churn, paper_workload)
    from repro.het.simulator import WorkerSpec
    from repro.het.spot import storm_market
    from repro.optim import sgd

    market = storm_market(4, zones=2, seed=STORM_SEED, horizon=12,
                          volatility=0.35, spike_rate=0.3,
                          degrade_rate=0.05, straggle_rate=0.08)
    trace = market.simulate()
    if args.csv:
        trace.to_csv(args.csv)
    churn = compile_churn(trace, min_workers=2)
    cycled = trace.summary()["cycled_fraction"]
    assert cycled >= 0.5, (
        f"storm mode needs a MASS storm (>=50% of workers cycled); "
        f"this trace only cycled {cycled:.0%}")
    _emit("churn/storm/cycled_fraction", cycled,
          f"{trace.summary()['preempts']} preempts + "
          f"{trace.summary()['rejoins']} rejoins over "
          f"{len(market.initial_fleet())} initial workers")

    def experiment(fleet, schedule):
        cluster = ClusterSpec.explicit(
            fleet, workload="mnist-cnn",
            backend=MeshBackend(mesh=mesh, dilation="from-spec",
                                growth=args.growth))
        if schedule:
            cluster = cluster.with_schedule(*schedule)
        return Experiment(
            workload=paper_workload("linreg"),
            cluster=cluster,
            optimizer=sgd(args.lr),
            config=TrainConfig(b0=args.b0, microbatch=4, batching="dynamic",
                               max_steps=args.steps, seed=args.seed),
        )

    def snapshot(session):
        t = session.trainer
        return {
            "step": t.step_idx,
            "batches": list(t.batches),
            "controller": t.controller.state_dict(),
            "exec": t.exec_state_dict(),
            "engine": (t.engine.version, list(t.engine.read_version)),
        }

    event_steps = sorted({ev.step for ev in churn.events})
    fireable = [s for s in event_steps if s < args.steps]
    save_step = max(fireable) if fireable else None

    s1 = experiment(market.initial_fleet(), churn.events).session()
    if save_step is not None:
        for _ in s1:
            if s1.step_idx >= save_step:
                break
        path = os.path.join(tempfile.mkdtemp(), "mid-storm")
        s1.save(path)
        snap1 = snapshot(s1)
        suffix = [ev for ev in churn.events if ev.step >= save_step]
        s2 = experiment([WorkerSpec(cores=8.0) for _ in range(s1.trainer.k)],
                        suffix).session()
        s2.restore(path)
        assert snapshot(s2) == snap1, \
            "mid-storm restore is not bit-identical"
        _emit("churn/storm/ckpt_bit_identical", 1,
              f"controller+exec+engine state equal after restore at "
              f"mid-storm step {save_step} (an event lands AT that step)")
        out2 = s2.run()
        _assert_conserved(out2["history"], "storm-resumed")
    else:
        _emit("churn/storm/ckpt_bit_identical", 0,
              f"skipped: no churn event before step {args.steps}")
    out1 = s1.run()
    total0 = _assert_conserved(out1["history"], "storm")
    t = s1.trainer
    _emit("churn/storm/global_batch", total0,
          f"conserved through {len([e for e in t.membership_log])} "
          f"membership-log entries on the mesh")
    per_worker = [sorted(b) for b in t.worker_buckets if b]
    worst = max(len(b) for b in per_worker)
    bound = max(
        math.ceil(math.log(b[-1] / b[0], args.growth)) + 1 if len(b) > 1
        else 1 for b in per_worker)
    assert worst <= bound, (
        f"per-worker bucket count {worst} exceeds the §11 ladder bound "
        f"{bound} under the storm: {per_worker}")
    _emit("churn/storm/recompiles_within_bound", 1,
          f"max {worst} buckets <= ladder bound {bound} through the storm")
    _emit("churn/storm/controller_events", t.controller.membership_events,
          f"membership/reallocate events absorbed; num_updates="
          f"{t.controller.num_updates} (checkpoint surface untouched)")


def run_chaos_mode(args) -> None:
    from repro.api import (ClusterSpec, Experiment, SimBackend, TrainConfig,
                           paper_workload)
    from repro.core import GlobalBatchConfig
    from repro.het.chaos import make_fault_plan, run_chaos
    from repro.optim import batch_coupled, sgd

    def make_session():
        exp = Experiment(
            workload=paper_workload("linreg"),
            cluster=ClusterSpec.hlevel(24, 3.0, 3, workload="linreg",
                                       seed=args.seed,
                                       backend=SimBackend()),
            optimizer=sgd(batch_coupled(args.lr, rule="linear")),
            config=TrainConfig(b0=4, microbatch=4, batching="dynamic",
                               max_steps=args.steps, seed=args.seed,
                               global_batch=GlobalBatchConfig(
                                   kind="gns", warmup=4, cooldown=4,
                                   gns_min_samples=4)),
        )
        return exp.session()

    # fault plans need >= 4 steps of room; the CI --steps 3 smoke still
    # runs (faults that never arm are reported via chaos_pending)
    plan = make_fault_plan(args.seed + 11, horizon=max(args.steps, 4))
    path = os.path.join(tempfile.mkdtemp(), "chaos-ckpt")
    r1, _h1 = run_chaos(make_session, plan, checkpoint_path=path)
    r2, _h2 = run_chaos(make_session, plan, checkpoint_path=path)
    assert r1["chaos_log"] == r2["chaos_log"], \
        "chaos injections did not replay identically"
    hist1 = [(r.step, r.loss, tuple(r.batches)) for r in r1["history"]]
    hist2 = [(r.step, r.loss, tuple(r.batches)) for r in r2["history"]]
    assert hist1 == hist2, "chaos-run training history is not deterministic"
    _emit("churn/chaos/deterministic", 1,
          f"two runs of fault plan seed={plan.seed} replayed "
          f"bit-identically ({len(hist1)} steps)")
    _emit("churn/chaos/faults_fired", len(r1["chaos_log"]),
          f"log={[(s, k) for s, k, _ in r1['chaos_log']]}")
    _emit("churn/chaos/faults_pending", r1["chaos_pending"],
          "armed faults whose trigger window never opened in this run "
          "(reported, never silently dropped)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mode", default="race",
                    choices=["race", "storm", "chaos"],
                    help="race = dynamic vs static flops-proportional on a "
                         "replayed >=32-worker spot trace (sim); storm = "
                         "mass preemption storm + mid-storm checkpoint on "
                         "the debug mesh; chaos = seeded fault-plan "
                         "determinism (sim)")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--devices", type=int, default=8,
                    help="fake CPU devices for the debug mesh (storm mode)")
    ap.add_argument("--workers", type=int, default=32,
                    help="spot fleet size for race mode (>= 32 for the "
                         "acceptance assertion)")
    ap.add_argument("--b0", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--growth", type=float, default=1.25)
    ap.add_argument("--target-slack", type=float, default=0.02,
                    help="relative slack on the static arm's final loss "
                         "when defining the shared time-to-target")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--csv", default=None,
                    help="also write the replayed churn trace "
                         "(step,kind,zone,slot,price,capacity,detail) to "
                         "this file (CI archives it)")
    ap.add_argument("--emit-json", default=None,
                    help="merge this run's rows into the per-PR "
                         "perf-trajectory artifact, e.g. BENCH_8.json "
                         "(benchmarks/artifact.py)")
    args = ap.parse_args()

    _force_cpu_devices(args.devices)

    print("name,value,derived")
    if args.mode == "race":
        run_race(args)
    elif args.mode == "storm":
        from repro.launch.mesh import make_debug_mesh

        run_storm(args, make_debug_mesh(args.devices))
    else:
        run_chaos_mode(args)
    if args.emit_json:
        import jax

        from benchmarks.artifact import rows_to_payload, update_bench_json

        update_bench_json(
            args.emit_json, f"churn_bench/{args.mode}", {
                "steps": args.steps,
                "rows": rows_to_payload(_ROWS),
            },
            meta={"jax": jax.__version__, "devices": args.devices})


if __name__ == "__main__":
    main()
