"""Sim-vs-mesh backend comparison (DESIGN.md §11).

Runs the SAME declarative Experiment twice — once on ``SimBackend``
(iteration times from the calibrated simulator) and once on ``MeshBackend``
(ragged SPMD on a multi-device CPU mesh, controller fed measured step times
with the cluster spec's heterogeneity emulated via time dilation) — and
reports controller convergence plus recompile counts against the bucket-
ladder bound.  Prints ``name,value,derived`` CSV like ``benchmarks/run.py``.

    PYTHONPATH=src python benchmarks/backend_bench.py [--steps 40]

The CI smoke job runs ``--steps 3`` as an end-to-end wiring check.  See
``benchmarks/README.md`` for how to read the output.
"""

from __future__ import annotations

import argparse
import math
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

_COUNT_FLAG = "--xla_force_host_platform_device_count"


def _force_cpu_devices(n: int) -> None:
    """Fake-device flags must land in XLA_FLAGS BEFORE jax initializes."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if _COUNT_FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{_COUNT_FLAG}={n} {flags}".strip()


def _imbalance(record) -> float:
    """max/min per-worker time in one BSP round — 1.0 = perfectly equalized,
    the quantity the paper's controller drives down."""
    times = record.worker_times
    return max(times) / max(min(times), 1e-12)


def _rows_for(name: str, session, out, growth: float) -> list:
    trainer = session.trainer
    hist = out["history"]
    rows = [
        (f"backend/{name}/steps", out["steps"], f"wall={out['wall_time']:.2f}s"),
        (f"backend/{name}/adjustments", out["batch_adjustments"],
         f"final_batches={out['final_batches']}"),
        (f"backend/{name}/imbalance_first", _imbalance(hist[0]),
         "max/min worker time, first round"),
        (f"backend/{name}/imbalance_last", _imbalance(hist[-1]),
         "max/min worker time, last round"),
        (f"backend/{name}/recompiles", trainer.accum_traces,
         f"jitted_calls={trainer.accum_calls}"),
    ]
    if hasattr(trainer, "worker_buckets"):  # mesh only
        per_worker = [sorted(b) for b in trainer.worker_buckets]
        worst = max(len(b) for b in per_worker)
        # ladder rungs grow >= growth, so per-worker compiles are bounded by
        # ceil(log_growth(bucket_max/bucket_min)) + 1 (DESIGN.md §11)
        bound = max(
            math.ceil(math.log(b[-1] / b[0], growth)) + 1 if len(b) > 1 else 1
            for b in per_worker)
        rows.append((f"backend/{name}/buckets_per_worker_max", worst,
                     f"ladder_bound={bound} buckets={per_worker}"))
        rows.append((f"backend/{name}/timing_reruns", trainer.timing_reruns,
                     "compile-time exclusions"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--devices", type=int, default=8,
                    help="fake CPU devices for the debug mesh")
    ap.add_argument("--workload", default="linreg",
                    choices=["linreg", "mnist-cnn", "resnet"])
    ap.add_argument("--b0", type=int, default=32)
    ap.add_argument("--hlevel", type=float, default=6.0)
    ap.add_argument("--growth", type=float, default=1.25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    _force_cpu_devices(args.devices)

    from repro.api import (ClusterSpec, Experiment, MeshBackend, SimBackend,
                           TrainConfig, paper_workload)
    from repro.launch.mesh import make_debug_mesh
    from repro.optim import adam, sgd

    opt = {"linreg": lambda: sgd(0.05), "mnist-cnn": lambda: adam(2e-3),
           "resnet": lambda: adam(2e-3)}[args.workload]

    def experiment(backend):
        return Experiment(
            workload=paper_workload(args.workload),
            cluster=ClusterSpec.hlevel(39, args.hlevel,
                                       workload=args.workload,
                                       seed=args.seed, backend=backend),
            optimizer=opt(),
            config=TrainConfig(b0=args.b0, microbatch=8, batching="dynamic",
                               max_steps=args.steps, seed=args.seed),
        )

    mesh = make_debug_mesh(args.devices)
    backends = [
        SimBackend(),
        MeshBackend(mesh=mesh, dilation="from-spec", growth=args.growth),
    ]

    print("name,value,derived")
    allocations = {}
    for backend in backends:
        exp = experiment(backend)
        session = exp.session()
        out = session.run()
        allocations[backend.name] = out["final_batches"]
        for row_name, value, derived in _rows_for(backend.name, session, out,
                                                  args.growth):
            print(f"{row_name},{float(value):.4g},{derived}")

    # how close do the two closed loops land? L1 distance between the
    # normalized final allocations (0 = identical shares)
    sim_b, mesh_b = allocations["sim"], allocations["mesh"]
    if len(sim_b) == len(mesh_b):
        s, m = sum(sim_b), sum(mesh_b)
        l1 = sum(abs(a / s - b / m) for a, b in zip(sim_b, mesh_b))
        print(f"backend/allocation_l1,{l1:.4g},"
              f"sim={sim_b} mesh={mesh_b}")


if __name__ == "__main__":
    main()
