"""Sim-vs-mesh backend comparison (DESIGN.md §11-§12).

``--mode compare`` (default) runs the SAME declarative Experiment twice —
once on ``SimBackend`` (iteration times from the calibrated simulator) and
once on ``MeshBackend`` (ragged SPMD over disjoint data-axis slices on a
multi-device CPU mesh, controller fed measured step times with the cluster
spec's heterogeneity emulated via time dilation) — and reports controller
convergence plus recompile counts against the bucket-ladder bound.  Under
BSP it also times an A/B of concurrent-slice vs sequential dispatch and
ASSERTS the concurrent round is cheaper (max-of-workers, not
sum-of-workers).  ``--sync asp`` compares the two backends' event-driven
ASP loops instead (staleness stats in place of per-round imbalance).

``--mode resume`` exercises mesh checkpointing: run, ``Session.save``,
restore into a fresh session, ASSERT the controller/EWMA/ladder state is
bit-identical, and continue training.

Prints ``name,value,derived`` CSV like ``benchmarks/run.py``.

    PYTHONPATH=src python benchmarks/backend_bench.py [--steps 40]
    PYTHONPATH=src python benchmarks/backend_bench.py --sync asp
    PYTHONPATH=src python benchmarks/backend_bench.py --mode resume

The CI smoke job runs ``--steps 3`` and ``--mode resume --steps 3`` as
end-to-end wiring checks.  See ``benchmarks/README.md`` for how to read
the output.
"""

from __future__ import annotations

import argparse
import math
import os
import statistics
import sys
import tempfile
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

_COUNT_FLAG = "--xla_force_host_platform_device_count"

# every CSV row also lands here so --emit-json can merge the run into the
# per-PR perf-trajectory artifact (benchmarks/artifact.py)
_ROWS: list = []


def _emit(name, value, derived) -> None:
    _ROWS.append((name, float(value), derived))
    print(f"{name},{float(value):.4g},{derived}")


def _force_cpu_devices(n: int) -> None:
    """Fake-device flags must land in XLA_FLAGS BEFORE jax initializes."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if _COUNT_FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{_COUNT_FLAG}={n} {flags}".strip()


def _imbalance(record) -> float:
    """max/min per-worker time in one BSP round — 1.0 = perfectly equalized,
    the quantity the paper's controller drives down."""
    times = record.worker_times
    return max(times) / max(min(times), 1e-12)


def _rows_for(name: str, session, out, growth: float, sync: str) -> list:
    trainer = session.trainer
    hist = out["history"]
    rows = [
        (f"backend/{name}/steps", out["steps"], f"wall={out['wall_time']:.2f}s"),
        (f"backend/{name}/adjustments", out["batch_adjustments"],
         f"final_batches={out['final_batches']}"),
    ]
    if sync == "bsp":
        rows += [
            (f"backend/{name}/imbalance_first", _imbalance(hist[0]),
             "max/min worker time, first round"),
            (f"backend/{name}/imbalance_last", _imbalance(hist[-1]),
             "max/min worker time, last round"),
        ]
    else:
        # ASP records carry staleness (global updates between a worker's
        # read and its write) in the straggler_waste column
        stale = [r.straggler_waste for r in hist]
        rows += [
            (f"backend/{name}/staleness_mean",
             sum(stale) / max(len(stale), 1), "mean update staleness"),
            (f"backend/{name}/staleness_max", max(stale),
             "worst update staleness"),
        ]
    rows.append((f"backend/{name}/recompiles", trainer.accum_traces,
                 f"jitted_calls={trainer.accum_calls}"))
    if hasattr(trainer, "worker_buckets"):  # mesh only
        per_worker = [sorted(b) for b in trainer.worker_buckets]
        worst = max(len(b) for b in per_worker)
        # ladder rungs grow >= growth, so per-worker compiles are bounded by
        # ceil(log_growth(bucket_max/bucket_min)) + 1 (DESIGN.md §11)
        bound = max(
            math.ceil(math.log(b[-1] / b[0], growth)) + 1 if len(b) > 1 else 1
            for b in per_worker)
        rows.append((f"backend/{name}/buckets_per_worker_max", worst,
                     f"ladder_bound={bound} buckets={per_worker}"))
        rows.append((f"backend/{name}/timing_reruns", trainer.timing_reruns,
                     "compile-time exclusions"))
        batches = [int(b) for b in out["final_batches"]]
        fetched = [trainer.bucket_for(w, n) for w, n in enumerate(batches)]
        over = (sum(fetched) - sum(batches)) / max(sum(fetched), 1)
        rows.append((f"backend/{name}/padding_overhead", over,
                     f"fraction of fetched rows that are ladder padding at "
                     f"the final allocation: buckets={fetched} "
                     f"batches={batches} (the rows the ragged kernel "
                     f"grid-skips — DESIGN.md §14)"))
        if trainer.slice_plan is not None:
            rows.append((f"backend/{name}/slice_widths",
                         len(trainer.slice_plan.slices),
                         f"slices={list(trainer.slice_plan.slices)}"))
    return rows


def _timed_rounds(make_experiment, concurrent: bool, rounds: int):
    """Median real wall time of a (post-warmup) BSP round in one dispatch
    mode, plus the last round's session.  Uniform batching pins the bucket
    shapes, so rounds after the first are compile-free and comparable
    across modes."""
    session = make_experiment(concurrent).session()
    walls = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        session.step()
        walls.append(time.perf_counter() - t0)
    steady = walls[2:] or walls
    return statistics.median(steady), session


def run_compare(args, mesh) -> None:
    from repro.api import (ClusterSpec, Experiment, MeshBackend, SimBackend,
                           TrainConfig, paper_workload)
    from repro.optim import adam, sgd

    opt = {"linreg": lambda: sgd(0.05), "mnist-cnn": lambda: adam(2e-3),
           "resnet": lambda: adam(2e-3)}[args.workload]

    def experiment(backend):
        return Experiment(
            workload=paper_workload(args.workload),
            cluster=ClusterSpec.hlevel(39, args.hlevel,
                                       workload=args.workload,
                                       seed=args.seed, backend=backend),
            optimizer=opt(),
            config=TrainConfig(b0=args.b0, microbatch=8, batching="dynamic",
                               sync=args.sync, max_steps=args.steps,
                               seed=args.seed),
        )

    backends = [
        SimBackend(),
        MeshBackend(mesh=mesh, dilation="from-spec", growth=args.growth),
    ]

    allocations = {}
    for backend in backends:
        exp = experiment(backend)
        session = exp.session()
        out = session.run()
        allocations[backend.name] = out["final_batches"]
        for row_name, value, derived in _rows_for(backend.name, session, out,
                                                  args.growth, args.sync):
            _emit(row_name, value, derived)

    # how close do the two closed loops land? L1 distance between the
    # normalized final allocations (0 = identical shares)
    sim_b, mesh_b = allocations["sim"], allocations["mesh"]
    if len(sim_b) == len(mesh_b):
        s, m = sum(sim_b), sum(mesh_b)
        l1 = sum(abs(a / s - b / m) for a, b in zip(sim_b, mesh_b))
        _emit("backend/allocation_l1", l1, f"sim={sim_b} mesh={mesh_b}")

    if args.sync != "bsp" or args.timing_rounds <= 0:
        return

    # --- concurrent-vs-sequential dispatch A/B (acceptance criterion:
    # a mesh BSP round costs max-of-workers, not sum-of-workers) ---
    # heavier per-worker compute than the comparison run, so execution
    # time (which overlaps) dominates dispatch overhead (which does not)
    def timing_experiment(concurrent):
        return Experiment(
            workload=paper_workload("mnist-cnn"),
            cluster=ClusterSpec.hlevel(
                39, args.hlevel, workload="mnist-cnn", seed=args.seed,
                backend=MeshBackend(mesh=mesh, concurrent=concurrent)),
            optimizer=adam(2e-3),
            config=TrainConfig(b0=128, microbatch=32, batching="uniform",
                               max_steps=args.timing_rounds, seed=args.seed),
        )

    seq, _ = _timed_rounds(timing_experiment, False, args.timing_rounds)
    con, con_sess = _timed_rounds(timing_experiment, True,
                                  args.timing_rounds)
    trainer = con_sess.trainer

    # (1) true concurrency: in the last concurrent round, every worker was
    # dispatched BEFORE the first one completed — all K calls in flight at
    # once with JAX async dispatch unblocked.  Robust on any host (unlike
    # the raw wall-clock A/B below: the debug mesh's fake CPU devices share
    # host cores, so compute-bound overlap depends on the core count).
    stamps = trainer.last_round_stamps
    assert stamps is not None and len(stamps) == trainer.k
    last_dispatch = max(t0 for t0, _ in stamps)
    first_done = min(done for _, done in stamps)
    in_flight_all = last_dispatch < first_done
    _emit("backend/mesh/concurrent_in_flight", float(in_flight_all),
          f"last_dispatch={last_dispatch - stamps[0][0]:.2e}s "
          f"first_completion={first_done - stamps[0][0]:.2e}s after round "
          f"start")
    assert in_flight_all, (
        "concurrent dispatch must have all workers in flight before the "
        f"first completes; stamps={stamps}")

    # (2) max-of-workers, not sum-of-workers: the round's in-flight window
    # (first dispatch → last completion) must be strictly smaller than the
    # sum of the per-slice dispatch→completion intervals.  Sequential
    # dispatch makes the two equal (each worker's interval IS its share of
    # the round); concurrent dispatch overlaps the waits, so the window
    # tracks the slowest worker.  The recorded iteration_time — what the
    # clock accumulates and the controller equalizes — is that max.
    window = max(done for _, done in stamps) - min(t0 for t0, _ in stamps)
    interval_sum = sum(done - t0 for t0, done in stamps)
    ratio_ws = window / max(interval_sum, 1e-12)
    rec = con_sess.history[-1]
    assert abs(rec.iteration_time - max(rec.worker_times)) < 1e-9, \
        "round time must be the max of per-worker completion intervals"
    assert ratio_ws < 0.9, (
        f"round window ({window:.4f}s) should be well under the sum of "
        f"per-slice intervals ({interval_sum:.4f}s): sequential dispatch "
        f"would make them equal (sum-of-workers)")
    _emit("backend/mesh/round_window_over_interval_sum", ratio_ws,
          f"in-flight window / Σ per-slice intervals; sequential dispatch "
          f"= ~1, perfect overlap = 1/k (k={trainer.k})")

    # (3) raw wall A/B, informational: on real disjoint accelerators the
    # concurrent round approaches max-of-workers wall time; on fake CPU
    # devices sharing few host cores the two modes converge instead, so
    # this row is reported but not asserted.
    ratio = con / max(seq, 1e-12)
    _emit("backend/mesh/round_wall_sequential", seq,
          "median steady-state round, time-multiplexed full axis")
    _emit("backend/mesh/round_wall_concurrent", con,
          "median steady-state round, disjoint slices in flight")
    _emit("backend/mesh/dispatch_concurrency_ratio", ratio,
          f"concurrent/sequential wall (host-core bound on the debug mesh; "
          f"<1 on genuinely disjoint hardware)")


def run_resume(args, mesh) -> None:
    """Mesh checkpoint mode: run → save → restore → assert bit-identical
    controller state → continue.  CSV row per check (value 1 = passed,
    the assertion fires before a 0 could ever be printed)."""
    from repro.api import (ClusterSpec, Experiment, MeshBackend, TrainConfig,
                           paper_workload)
    from repro.optim import sgd

    def experiment():
        return Experiment(
            workload=paper_workload(args.workload),
            cluster=ClusterSpec.hlevel(39, args.hlevel,
                                       workload=args.workload,
                                       seed=args.seed,
                                       backend=MeshBackend(
                                           mesh=mesh, dilation="from-spec",
                                           growth=args.growth)),
            optimizer=sgd(0.05),
            config=TrainConfig(b0=args.b0, microbatch=8, batching="dynamic",
                               max_steps=2 * args.steps, seed=args.seed),
        )

    def state(session):
        # the product state surface itself (EWMA/rates/clock/buckets/
        # slices/dilation), so new exec-state fields are covered as added
        t = session.trainer
        return {
            "step": t.step_idx,
            "batches": list(t.batches),
            "controller": t.controller.state_dict(),
            "exec": t.exec_state_dict(),
            "engine": (t.engine.version, list(t.engine.read_version)),
        }

    path = os.path.join(tempfile.mkdtemp(), "mesh-ckpt")
    first = experiment().session()
    for i, _rec in enumerate(first):
        if i + 1 >= args.steps:
            break
    first.save(path)
    resumed = experiment().session()
    resumed.restore(path)
    assert state(resumed) == state(first), \
        "restored controller/measurement state is not bit-identical"
    _emit("resume/state_bit_identical", 1,
          f"controller+EWMA+rates+ladder after restore at step {args.steps}")
    out = resumed.run()
    assert out["steps"] == 2 * args.steps
    _emit("resume/continued_steps", out["steps"] - args.steps,
          f"steps trained after restore (of {args.steps} expected)")
    _emit("resume/final_loss", out["final_loss"],
          "finite loss after resumed training")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mode", default="compare",
                    choices=["compare", "resume"],
                    help="compare = sim-vs-mesh; resume = mesh "
                         "save→restore→continue checkpoint check")
    ap.add_argument("--sync", default="bsp", choices=["bsp", "asp"],
                    help="synchronization mode for the comparison runs")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--devices", type=int, default=8,
                    help="fake CPU devices for the debug mesh")
    ap.add_argument("--workload", default="linreg",
                    choices=["linreg", "mnist-cnn", "resnet"])
    ap.add_argument("--b0", type=int, default=32)
    ap.add_argument("--hlevel", type=float, default=6.0)
    ap.add_argument("--growth", type=float, default=1.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timing-rounds", type=int, default=8,
                    help="rounds for the concurrent-vs-sequential dispatch "
                         "A/B (0 disables; BSP compare mode only)")
    ap.add_argument("--emit-json", default=None,
                    help="merge this run's rows (step medians, recompiles, "
                         "padding overhead) into the per-PR perf-trajectory "
                         "artifact, e.g. BENCH_6.json (benchmarks/artifact.py)")
    args = ap.parse_args()

    _force_cpu_devices(args.devices)

    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh(args.devices)
    print("name,value,derived")
    if args.mode == "compare":
        run_compare(args, mesh)
    else:
        run_resume(args, mesh)
    if args.emit_json:
        import jax

        from benchmarks.artifact import rows_to_payload, update_bench_json

        update_bench_json(
            args.emit_json, f"backend_bench/{args.mode}_{args.sync}", {
                "steps": args.steps,
                "rows": rows_to_payload(_ROWS),
            },
            meta={"jax": jax.__version__, "devices": args.devices})


if __name__ == "__main__":
    main()
