"""Ragged Pallas flash-attention vs masked reference on the hot path.

Sweeps the bucket ladder (DESIGN.md §11) with the ragged kernel
(DESIGN.md §14) against the masked jnp reference, on the same CPU debug
mesh the backend benchmarks use:

  * **grad exactness** — on EVERY ladder rung, for ``num_valid`` in
    {0, rung/2, rung}, kernel-path gradients (Pallas forward + Pallas
    backward) must match the masked ``attention_ref`` gradients (fp32
    allclose), and one compiled executable must serve all valid counts
    (``num_valid`` is a traced operand, never a shape).
  * **step-time ladder sweep** — fwd+bwd step-time medians, kernel vs
    reference, per rung.
  * **padding skip** — the acceptance criterion: a bucket at half
    occupancy (``num_valid = b_max/2``) must cost within 15% of the
    half-size bucket, while the masked reference pays for every padded
    row (~2x).  Measured ratios are checked against the roofline
    compute-term prediction (time proportional to useful FLOPs, which are
    proportional to valid rows — ``launch/roofline.py``).
  * **debug-mesh wiring** — the SAME uniform-batching lm Experiment run
    through ``MeshBackend`` with ``lm_workload(use_kernel=True)`` vs
    ``False``; final losses must agree (the trainer's suffix-padding mask
    and the kernel's ``num_valid`` are one source of truth).

Prints ``name,value,derived`` CSV (``--csv`` also writes it to a file) and
merges a ``kernel_bench`` section into the per-PR perf-trajectory artifact
(``--emit-json``, default ``BENCH_6.json`` at the repo root — see
``benchmarks/artifact.py``).  Timing assertions arm at ``--steps >= 30``
(medians need steady state); CI smokes with ``--steps 3``.

    PYTHONPATH=src python benchmarks/kernel_bench.py [--steps 30]

CPU note: Pallas runs in interpret mode here (``jax.default_backend() ==
"cpu"``), where ``ragged_impl="auto"`` selects the rowloop lowering — the
batch-grid axis as a ``fori_loop`` with a traced trip count, semantically
the TPU kernel's sequential batch axis (kernels/flash_attention/kernel.py).
"""

from __future__ import annotations

import argparse
import os
import statistics
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks.artifact import rows_to_payload, update_bench_json

_COUNT_FLAG = "--xla_force_host_platform_device_count"


def _force_cpu_devices(n: int) -> None:
    """Fake-device flags must land in XLA_FLAGS BEFORE jax initializes."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if _COUNT_FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{_COUNT_FLAG}={n} {flags}".strip()


# ------------------------------------------------------------ step harness


def _step_fn(use_kernel: bool):
    """Jitted fwd+bwd attention step: weighted-sum loss, grads wrt q/k/v.

    ``num_valid`` rides along as a traced operand, so every valid count in
    a bucket hits the same executable (asserted below).
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention.ops import attention

    def loss(q, k, v, nv, w):
        out = attention(q, k, v, num_valid=nv, use_kernel=use_kernel,
                        interpret=True)
        return (out.astype(jnp.float32) * w).sum()

    return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))


def _data(key, b, s, h, hkv, d):
    import jax
    import jax.numpy as jnp

    kq, kk, kv, kw = jax.random.split(key, 4)
    return (jax.random.normal(kq, (b, s, h, d), jnp.float32),
            jax.random.normal(kk, (b, s, hkv, d), jnp.float32),
            jax.random.normal(kv, (b, s, hkv, d), jnp.float32),
            jax.random.normal(kw, (b, s, h, d), jnp.float32))


def _median_ms(fn, fargs, steps: int) -> float:
    import jax

    jax.block_until_ready(fn(*fargs))  # compile outside the timed region
    walls = []
    for _ in range(max(steps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*fargs))
        walls.append((time.perf_counter() - t0) * 1e3)
    return statistics.median(walls)


def _max_abs_err(ga, gb) -> tuple[float, float]:
    """(max |ga - gb|, max |gb|) over two (dq, dk, dv) triples."""
    import jax.numpy as jnp

    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(ga, gb))
    scale = max(float(jnp.max(jnp.abs(b))) for b in gb)
    return err, scale


# ------------------------------------------------------------------ sweeps


def run_ladder(args) -> tuple[list, dict]:
    """Grad exactness on every rung + step-time medians kernel vs ref.

    Returns (rows, cache) where cache holds the compiled step fns and data
    for the top rung, reused by the padding-skip section (the kernel's
    traced ``num_valid`` means half-occupancy needs no new executable).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import bucket_ladder

    rungs = bucket_ladder(args.b_max, base=1, growth=args.growth, quantum=1)
    key = jax.random.PRNGKey(args.seed)
    kfn, rfn = _step_fn(True), _step_fn(False)

    rows = []
    cache = {}
    for b in rungs:
        q, k, v, w = _data(jax.random.fold_in(key, b), b, args.seq,
                           args.heads, args.kv_heads, args.head_dim)
        for nv in sorted({0, b // 2, b}):
            nv_ = jnp.int32(nv)
            lk, gk = kfn(q, k, v, nv_, w)
            lr, gr = rfn(q, k, v, nv_, w)
            err, scale = _max_abs_err(gk, gr)
            ok = all(
                jnp.allclose(a.astype(jnp.float32), c.astype(jnp.float32),
                             atol=5e-4, rtol=5e-3)
                for a, c in zip(gk, gr)) and jnp.allclose(
                    lk, lr, atol=5e-3, rtol=5e-4)
            rows.append((f"kernel/grad/b{b}/nv{nv}/max_abs_err", err,
                         f"vs masked ref; grad_scale={scale:.3g} "
                         f"loss={float(lk):.6g} ref={float(lr):.6g}"))
            assert ok, (
                f"kernel-path gradients diverged from the masked reference "
                f"at bucket {b}, num_valid {nv}: max_abs_err={err:.3g} "
                f"(grad scale {scale:.3g})")
        n_exec = kfn._cache_size()
        rows.append((f"kernel/bucket{b}/executables", n_exec,
                     "one executable serves every valid count in the bucket"))
        assert n_exec == len(rungs[:rungs.index(b) + 1]), (
            f"num_valid must be traced, not a shape: bucket {b} has "
            f"{n_exec} executables after {rungs.index(b) + 1} rungs")

        nv_full = jnp.int32(b)
        t_k = _median_ms(kfn, (q, k, v, nv_full, w), args.steps)
        t_r = _median_ms(rfn, (q, k, v, nv_full, w), args.steps)
        rows.append((f"kernel/bucket{b}/step_ms", t_k,
                     f"fwd+bwd median of {args.steps}, num_valid={b} (full)"))
        rows.append((f"ref/bucket{b}/step_ms", t_r,
                     f"kernel/ref={t_k / max(t_r, 1e-9):.3g} (interpret-mode "
                     f"kernel vs XLA-fused jnp on CPU — see DESIGN.md §14)"))
        cache[b] = (q, k, v, w)
    cache["fns"] = (kfn, rfn)
    cache["rungs"] = rungs
    return rows, cache


def run_padding_skip(args, cache) -> list:
    """The acceptance measurement: half-occupied bucket vs half-size bucket.

    Kernel: rows past ``num_valid`` are skipped by the grid, so the ratio
    must sit within 15% of 1.0 (the roofline compute-term prediction —
    useful FLOPs are proportional to valid rows).  Masked reference:
    computes every padded row then zeros it, predicting ~2.0.
    """
    import jax
    import jax.numpy as jnp

    from repro.launch.roofline import PEAK_FLOPS

    B = args.b_max if args.b_max % 2 == 0 else args.b_max - 1
    half = B // 2
    kfn, rfn = cache["fns"]
    if B in cache:
        qB, kB, vB, wB = cache[B]
    else:
        qB, kB, vB, wB = _data(jax.random.PRNGKey(args.seed + 1), B,
                               args.seq, args.heads, args.kv_heads,
                               args.head_dim)
    qh, kh, vh, wh = _data(jax.random.PRNGKey(args.seed + 2), half,
                           args.seq, args.heads, args.kv_heads,
                           args.head_dim)
    nv = jnp.int32(half)

    t_k_pad = _median_ms(kfn, (qB, kB, vB, nv, wB), args.steps)
    t_k_half = _median_ms(kfn, (qh, kh, vh, nv, wh), args.steps)
    t_r_pad = _median_ms(rfn, (qB, kB, vB, nv, wB), args.steps)
    t_r_half = _median_ms(rfn, (qh, kh, vh, nv, wh), args.steps)
    r_kernel = t_k_pad / max(t_k_half, 1e-9)
    r_ref = t_r_pad / max(t_r_half, 1e-9)

    # roofline compute-term prediction: attention matmul FLOPs scale with
    # valid rows, so grid-skip predicts 1.0 and mask-only predicts B/(B/2)
    flops_fwd = 4.0 * half * args.heads * args.seq * args.seq \
        * args.head_dim * 0.5  # QK^T + PV, causal halves the visible tiles
    flops_step = 3.5 * flops_fwd  # + backward (recompute + 5 matmuls)
    armed = args.steps >= 30

    rows = [
        (f"kernel/pad_skip/half_valid_ms", t_k_pad,
         f"bucket {B}, num_valid={half} — padded rows grid-skipped"),
        (f"kernel/pad_skip/half_size_ms", t_k_half,
         f"bucket {half}, num_valid={half} — the work actually needed"),
        (f"kernel/pad_skip/ratio", r_kernel,
         f"half-valid/half-size; acceptance <= 1.15 "
         + ("(asserted)" if armed
            else f"(informational at --steps {args.steps})")),
        (f"ref/pad_skip/padded_ms", t_r_pad,
         f"bucket {B} masked to {half} rows — every padded row computed"),
        (f"ref/pad_skip/half_size_ms", t_r_half, f"bucket {half}"),
        (f"ref/pad_skip/ratio", r_ref,
         f"mask-only pays for padding; roofline predicts {B / half:.1f}"),
        (f"roofline/kernel_pad_ratio_pred", 1.0,
         f"measured={r_kernel:.3g}; useful-FLOPs proportionality "
         f"(launch/roofline.py compute term)"),
        (f"roofline/ref_pad_ratio_pred", float(B) / half,
         f"measured={r_ref:.3g}"),
        (f"roofline/attn_step_flops", flops_step,
         f"half-size bucket fwd+bwd matmul FLOPs (estimate); v5e compute "
         f"term {flops_step / PEAK_FLOPS * 1e3:.4g} ms at "
         f"{PEAK_FLOPS / 1e12:.0f} TFLOP/s"),
    ]
    if armed:
        assert abs(r_kernel - 1.0) <= 0.15, (
            f"padding-skip regressed: half-valid bucket {B} cost "
            f"{r_kernel:.3f}x the half-size bucket (acceptance: within "
            f"15%); padded rows are costing kernel FLOPs")
        assert r_ref >= 1.5, (
            f"reference baseline suspicious: masked bucket {B} only "
            f"{r_ref:.3f}x its half-size bucket — the comparison baseline "
            f"should pay ~2x for padding")
    return rows


def run_mesh(args, mesh) -> list:
    """End-to-end wiring on the debug mesh: lm Experiment, kernel vs ref.

    Uniform batching pins shapes and batches, so the two runs consume
    identical data and must land on the same loss — the trainer's
    suffix-padding mask and the kernel's ``num_valid`` are one source of
    truth (train/mesh.py, DESIGN.md §14).  b0=6 buckets up to 7, so every
    worker step carries a real padded row through the kernel.
    """
    from repro.api import (ClusterSpec, Experiment, MeshBackend, TrainConfig,
                           lm_workload)
    from repro.configs import get_config
    from repro.data import DataPipeline
    from repro.models import reduced
    from repro.optim import adam

    rows, outs = [], {}
    for use_kernel in (False, True):
        cfg = reduced(get_config("gemma-2b"))
        pipe = DataPipeline(cfg, seq_len=128, num_workers=3, seed=args.seed)
        exp = Experiment(
            workload=lm_workload(cfg, pipe, use_kernel=use_kernel),
            cluster=ClusterSpec.hlevel(
                39, args.hlevel, 3, workload="transformer", seed=args.seed,
                backend=MeshBackend(mesh=mesh, dilation="from-spec",
                                    growth=args.growth)),
            optimizer=adam(1e-3),
            config=TrainConfig(b0=6, microbatch=6, batching="uniform",
                               max_steps=args.mesh_steps, seed=args.seed),
        )
        session = exp.session()
        out = session.run()
        name = "kernel" if use_kernel else "ref"
        outs[name] = out
        rows.append((f"mesh/{name}/final_loss", out["final_loss"],
                     f"{out['steps']} uniform BSP steps, b0=6 -> bucket 7 "
                     f"(1 padded row per worker)"))
        rows.append((f"mesh/{name}/recompiles",
                     session.trainer.accum_traces,
                     f"jitted_calls={session.trainer.accum_calls}"))
        rows.append((f"mesh/{name}/wall_per_step",
                     out["wall_time"] / max(out["steps"], 1),
                     "debug-mesh wall seconds per BSP round"))
    rel = (abs(outs["kernel"]["final_loss"] - outs["ref"]["final_loss"])
           / max(abs(outs["ref"]["final_loss"]), 1e-9))
    rows.append(("mesh/loss_rel_err", rel,
                 "kernel vs reference workload after identical uniform "
                 "steps (asserted < 1e-3)"))
    assert rel < 1e-3, (
        f"lm_workload(use_kernel=True) diverged from the reference path on "
        f"the mesh: final losses {outs['kernel']['final_loss']} vs "
        f"{outs['ref']['final_loss']}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--steps", type=int, default=30,
                    help="timed reps per point; timing assertions arm at "
                         ">= 30 (CI smokes with 3)")
    ap.add_argument("--devices", type=int, default=8,
                    help="fake CPU devices for the debug mesh")
    ap.add_argument("--seq", type=int, default=128,
                    help="sequence length (must be a multiple of 128)")
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=64,
                    help="64 exercises the lane-padding path (< 128 lanes)")
    ap.add_argument("--b-max", type=int, default=16,
                    help="top of the bucket ladder swept")
    ap.add_argument("--growth", type=float, default=1.25)
    ap.add_argument("--hlevel", type=float, default=6.0,
                    help="cluster heterogeneity for the mesh wiring check")
    ap.add_argument("--mesh-steps", type=int, default=3,
                    help="training steps for the debug-mesh wiring check")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--csv", default=None,
                    help="also write the CSV rows to this file")
    ap.add_argument("--emit-json",
                    default=os.path.join(_ROOT, "BENCH_6.json"),
                    help="perf-trajectory artifact to merge the "
                         "kernel_bench section into ('' disables)")
    args = ap.parse_args()

    _force_cpu_devices(args.devices)

    import jax

    from repro.launch.mesh import make_debug_mesh

    rows = [("kernel/config/geometry", args.b_max,
             f"b_max x seq {args.seq} x heads {args.heads}/{args.kv_heads} "
             f"x head_dim {args.head_dim}, growth {args.growth}, "
             f"steps {args.steps}")]
    ladder_rows, cache = run_ladder(args)
    rows += ladder_rows
    rows += run_padding_skip(args, cache)
    rows += run_mesh(args, make_debug_mesh(args.devices))

    print("name,value,derived")
    lines = [f"{name},{float(value):.4g},{derived}"
             for name, value, derived in rows]
    print("\n".join(lines))
    if args.csv:
        with open(args.csv, "w") as f:
            f.write("name,value,derived\n" + "\n".join(lines) + "\n")
    if args.emit_json:
        update_bench_json(
            args.emit_json, "kernel_bench", {
                "steps": args.steps,
                "timing_asserts_armed": args.steps >= 30,
                "rows": rows_to_payload(rows),
            },
            meta={"jax": jax.__version__, "backend": jax.default_backend(),
                  "devices": args.devices})


if __name__ == "__main__":
    main()
