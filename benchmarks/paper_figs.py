"""One benchmark per paper figure (Tyagi & Sharma).

Each function returns a list of CSV rows (name, value, derived) and is
invoked by benchmarks.run. Training benchmarks perform REAL SGD on the
paper's (scaled-down) workloads; wall-time comes from the calibrated
heterogeneity simulator (DESIGN.md §2).
"""

from __future__ import annotations

import numpy as np

from repro.api import ClusterSpec, Experiment, TrainConfig, paper_workload
from repro.core import ControllerConfig
from repro.het import (
    WORKLOADS,
    ClusterSim,
    hlevel_cluster,
    homogeneous_cluster,
    mixed_gpu_cpu_cluster,
)
from repro.optim import adam, sgd
from repro.train.metrics import batch_trajectory, iteration_time_stats

TARGETS = {"linreg": 0.02, "mnist-cnn": 0.9, "resnet": 1.7}
OPTS = {"linreg": lambda: sgd(0.05), "mnist-cnn": lambda: adam(2e-3),
        "resnet": lambda: adam(2e-3)}


def _train(workload, workers, mode, *, steps=80, target=None, seed=0,
           controller=None, sync="bsp", b0=32):
    return Experiment(
        workload=paper_workload(workload, seed=100),
        cluster=ClusterSpec.explicit(workers, workload=workload, seed=seed),
        optimizer=OPTS[workload](),
        config=TrainConfig(
            b0=b0, microbatch=8, batching=mode, sync=sync, max_steps=steps,
            target_loss=target, seed=seed,
            controller=controller or ControllerConfig()),
    ).run()


# ---------------------------------------------------------------- figure 1


def fig1_heterogeneity_slowdown():
    """Training-time increase on a heterogeneous vs homogeneous cluster with
    the SAME total resources, uniform batching (paper Fig. 1)."""
    rows = []
    for workload in ("resnet", "mnist-cnn", "linreg"):
        steps = 40
        hom = _train(workload, homogeneous_cluster(39), "uniform", steps=steps)
        het = _train(workload, hlevel_cluster(39, 6), "uniform", steps=steps)
        slowdown = het["sim_time"] / hom["sim_time"]
        rows.append((f"fig1/{workload}/slowdown_h6", slowdown,
                     f"hom={hom['sim_time']:.1f}s het={het['sim_time']:.1f}s"))
    return rows


# ---------------------------------------------------------------- figure 3


def fig3_iteration_time_distributions():
    """Per-worker iteration-time spread: uniform vs variable batching on a
    (3, 5, 12)-like cores cluster (paper Fig. 3)."""
    rows = []
    for mode in ("uniform", "static"):
        out = _train("resnet", hlevel_cluster(20, 4), mode, steps=30)
        times = np.asarray(
            [[WORKLOADS["resnet"].t_sync] for _ in out["history"]])
        # per-worker times from the simulator model at final batches
        sim = ClusterSim(hlevel_cluster(20, 4), WORKLOADS["resnet"], seed=1)
        per_worker = [
            [sim.iteration_time(k, b) for _ in range(200)]
            for k, b in enumerate(out["final_batches"])]
        spread = (np.mean([np.mean(t) for t in per_worker])
                  and np.std([np.mean(t) for t in per_worker])
                  / np.mean([np.mean(t) for t in per_worker]))
        rows.append((f"fig3/{mode}/worker_mean_time_cv", spread,
                     f"batches={out['final_batches']}"))
    return rows


# ---------------------------------------------------------------- figure 4


def fig4_controller_convergence():
    """(a) convergence in ~2 adjustments from uniform init; (b) oscillation
    without dead-banding (paper Fig. 4)."""
    from repro.core import DynamicBatchController

    xput = [1.0, 2.0, 3.0]
    rows = []
    # (a) with dead-band
    ctrl = DynamicBatchController([32, 32, 32])
    for _ in range(30):
        ctrl.observe([b / x for b, x in zip(ctrl.batches, xput)])
    rows.append(("fig4a/adjustments_to_converge", ctrl.num_updates,
                 f"final={ctrl.batches}"))
    # (b) without dead-band, noisy times
    rng = np.random.default_rng(0)
    ctrl2 = DynamicBatchController(
        [32, 32, 32], ControllerConfig(dead_band=0.0, ewma_alpha=1.0,
                                       adaptive_bmax=False))
    for _ in range(30):
        ctrl2.observe([max(b / x * (1 + 0.1 * rng.standard_normal()), 1e-3)
                       for b, x in zip(ctrl2.batches, xput)])
    rows.append(("fig4b/adjustments_without_deadband", ctrl2.num_updates,
                 "oscillates (paper Fig. 4b)"))
    return rows


# ---------------------------------------------------------------- figure 5


def fig5_throughput_vs_batch():
    """Throughput rises with batch then falls past the memory limit
    (paper Fig. 5)."""
    from repro.het import WorkerSpec

    rows = []
    for kind, b_mem in (("gpu", 64), ("cpu", 256)):
        spec = WorkerSpec(cores=8 if kind == "cpu" else 1,
                          flops_ratio=1.0 if kind == "cpu" else 30.0,
                          kind=kind, b_mem=b_mem)
        sim = ClusterSim([spec], WORKLOADS["mnist-cnn"], noise=0.0)
        batches = (8, 16, 32, 64, 128, 256, 512, 1024, 2048)
        curve = {b: sim.throughput(0, b) for b in batches}
        peak_b = max(curve, key=curve.get)
        rows.append((f"fig5/{kind}/peak_batch", peak_b,
                     " ".join(f"{b}:{x:.0f}" for b, x in curve.items())))
        # decline past the cliff: sharp for GPU, gradual for CPU (Fig. 5)
        assert curve[batches[-1]] < curve[peak_b]
    return rows


# ---------------------------------------------------------------- figure 6


def fig6_time_to_accuracy_vs_hlevel(quick: bool = True):
    """The headline result: training time to target, uniform vs variable,
    across H-levels (paper Fig. 6: up to 4x)."""
    rows = []
    hlevels = (1.0, 2.0, 6.0, 10.0) if quick else (1, 2, 4, 6, 8, 10)
    workloads = ("resnet", "mnist-cnn", "linreg")
    steps = {"resnet": 50, "mnist-cnn": 60, "linreg": 150}
    for workload in workloads:
        base = None
        for h in hlevels:
            workers = (homogeneous_cluster(39) if h == 1.0
                       else hlevel_cluster(39, h))
            uni = _train(workload, list(workers), "uniform",
                         steps=steps[workload])
            dyn = _train(workload, list(workers), "dynamic",
                         steps=steps[workload])
            if h == 1.0:
                base = uni["sim_time"]
            speedup = uni["sim_time"] / dyn["sim_time"]
            rows.append((f"fig6/{workload}/h{h:g}/speedup", speedup,
                         f"uni={uni['sim_time']:.1f}s dyn={dyn['sim_time']:.1f}s "
                         f"vs_hom={uni['sim_time']/base:.2f}x"))
    return rows


# ---------------------------------------------------------------- figure 7


def fig7_gpu_cpu_mixed():
    """Mixed GPU+CPU cluster: uniform vs variable (open-loop) vs dynamic
    (paper Fig. 7a; paper reports >4x for ResNet, ~20% for MNIST)."""
    rows = []
    for workload in ("resnet", "mnist-cnn"):
        steps = 30 if workload == "resnet" else 40
        res = {}
        for mode in ("uniform", "static", "dynamic"):
            out = _train(workload, mixed_gpu_cpu_cluster(), mode,
                         steps=steps, b0=64)
            res[mode] = out["sim_time"]
        rows.append((f"fig7/{workload}/variable_speedup",
                     res["uniform"] / res["static"],
                     f"uniform={res['uniform']:.1f}s static={res['static']:.1f}s "
                     f"dynamic={res['dynamic']:.1f}s"))
        rows.append((f"fig7/{workload}/dynamic_vs_static",
                     res["static"] / res["dynamic"], ""))
    return rows


# --------------------------------------------------------- ASP (section IV)


def asp_comparison():
    """BSP vs ASP under heterogeneity with and without variable batching."""
    rows = []
    for mode in ("uniform", "dynamic"):
        out = _train("linreg", hlevel_cluster(39, 6), mode, steps=120,
                     sync="asp")
        rows.append((f"asp/{mode}/final_loss", out["final_loss"],
                     f"time={out['sim_time']:.1f}s"))
    return rows
