"""Ablations beyond the paper's tables.

 * controller variants: full paper controller vs no-EWMA vs no-dead-band vs
   the beyond-paper zero-cost-resize controller, under dynamic interference;
 * static-vs-dynamic under open-loop estimation error (paper §III-C's
   motivation: FLOPs don't predict throughput exactly);
 * MoE dispatch group-size sweep (dry-run bytes, if results file present).
"""

from __future__ import annotations

import numpy as np

from repro.api import ClusterSpec, Experiment, TrainConfig, paper_workload
from repro.core import ControllerConfig
from repro.het import hlevel_cluster, traces
from repro.optim import adam


def _experiment(mode, workers, controller, steps, seed=0,
                workload="mnist-cnn"):
    return Experiment(
        workload=paper_workload(workload, seed=seed),
        cluster=ClusterSpec.explicit(workers, workload=workload, seed=seed),
        optimizer=adam(2e-3),
        config=TrainConfig(b0=32, microbatch=8, batching=mode,
                           max_steps=steps, controller=controller),
    )


def controller_variants():
    """Interference hits mid-run; measure recovery time and adjustments.

    Covers the paper's P-law ablations plus the control-layer plugins
    (PI / full PID / gain-scheduled — DESIGN.md §3)."""
    variants = {
        "paper": ControllerConfig(),
        "no-ewma": ControllerConfig(ewma_alpha=1.0),
        "no-deadband": ControllerConfig(dead_band=0.0),
        "beyond-paper": ControllerConfig(beyond_paper=True),
        "pi": ControllerConfig(kind="pi"),
        "pid": ControllerConfig(kind="pid"),
        "gain-scheduled": ControllerConfig(kind="gain"),
    }
    rows = []
    for name, ctrl_cfg in variants.items():
        workers = hlevel_cluster(39, 4)
        workers[-1].trace = traces.step_interference(4.0, 1e9, 0.3)
        out = _experiment("dynamic", workers, ctrl_cfg, steps=50).run()
        # recovery: first adjustment after the interference hits
        hit_step = next((r.step for r in out["history"] if r.sim_time >= 4.0),
                        None)
        adj_after = next((r.step for r in out["history"]
                          if r.adjusted and r.step > (hit_step or 0)), None)
        recovery = (adj_after - hit_step) if (hit_step is not None
                                              and adj_after is not None) else -1
        rows.append((f"ablation/controller/{name}/sim_time",
                     out["sim_time"],
                     f"adjustments={out['batch_adjustments']} "
                     f"recovery_steps={recovery}"))
    return rows


def openloop_estimation_error():
    """Static allocation from *wrong* throughput estimates vs dynamic
    correction (paper: Amdahl makes core counts mispredict throughput)."""
    rows = []
    workers = hlevel_cluster(39, 6)
    # static policy fed raw core counts (ignores Amdahl) via init allocation:
    out_s = _experiment("static", workers, ControllerConfig(), steps=40).run()
    out_d = _experiment("dynamic", workers, ControllerConfig(), steps=40).run()
    rows.append(("ablation/openloop/static_time", out_s["sim_time"],
                 f"batches={out_s['final_batches']}"))
    rows.append(("ablation/openloop/dynamic_time", out_d["sim_time"],
                 f"batches={out_d['final_batches']} "
                 f"corrects_estimation_error="
                 f"{out_d['sim_time'] < out_s['sim_time'] * 1.01}"))
    return rows


def moe_group_size_sweep(results_path="dryrun_results.json"):
    """Report MoE dispatch bytes sensitivity from recorded dry-runs (the
    dispatch tensor is (g, E, cap) per group; group size trades VMEM for
    dispatch overhead)."""
    import json
    import os

    if not os.path.exists(results_path):
        return [("ablation/moe_group/skipped", 0.0, "no dryrun results")]
    with open(results_path) as f:
        results = json.load(f)
    rows = []
    for r in results:
        if (r.get("status") == "ok" and r["mesh"] == "16x16"
                and r["arch"] in ("grok-1-314b", "deepseek-v2-236b")
                and r["shape"] == "train_4k"):
            p = r.get("probe", {})
            rows.append((f"ablation/moe/{r['arch']}/bytes_per_dev",
                         p.get("bytes_accessed_total", 0.0),
                         f"group_size=1024 cap_factor=1.25"))
    return rows
