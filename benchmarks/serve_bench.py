"""Production-shape serving benchmark (DESIGN.md §17).

``--mode latency`` (default): the disaggregation A/B.  The SAME seeded
Poisson arrival process (bit-identical replay, see ``serve/traffic.py``)
is fed to the PR-5 admission path (``ContinuousBatcher``: prefill runs
token-by-token through the decode step inside the serving loop) and to
the disaggregated engine (``PrefillProgram`` + ``KVSlotManager``: one
bucketed scan per prompt, decode slots fed from the handoff queue).  Both
engines decode the same model on the same device; the CSV compares
whole-step wall percentiles.  Prefill work hides inside step walls either
way — disaggregation wins because a P-token admission costs one fused
scan instead of P sequential decode calls, which is exactly what the p95
(the steps that admit) measures.

``--mode diurnal``: production-shape co-location.  A dedicated-slice
trainer with the disaggregated engine rides a diurnal arrival envelope;
the SLO policy must oscillate training's device count (>=1 grow AND >=1
shrink through the membership replan path) while training still reaches
its loss target and the controller conserves the global batch Σb_k every
round.  The replayed trace is written as CSV (``--trace-csv``) so the run
is auditable and replayable.

Prints ``name,value,derived`` CSV like the other drivers.

    PYTHONPATH=src python benchmarks/serve_bench.py [--steps 60]
    PYTHONPATH=src python benchmarks/serve_bench.py --mode diurnal

Assertions are armed when ``--steps`` >= 30; CI smokes both modes with
``--steps 6`` as wiring checks.  See ``benchmarks/README.md``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from backend_bench import _force_cpu_devices  # noqa: E402

_ROWS: list = []


def _emit(name, value, derived) -> None:
    _ROWS.append((name, float(value), derived))
    print(f"{name},{float(value):.4g},{derived}")


def _pct(xs, q):
    import numpy as np

    return float(np.percentile(xs, q)) if xs else 0.0


# --------------------------------------------------------------- latency A/B


def _replay(engine, traffic, steps, max_drain=4000):
    """Feed one seeded arrival stream into an engine, stepping once per
    round, then drain; returns (per-step walls in ms, finished count)."""
    walls = []
    for _ in range(steps):
        for req in traffic.next_round():
            engine.submit(req)
        t0 = time.perf_counter()
        engine.step()
        walls.append(1e3 * (time.perf_counter() - t0))
    traffic.rate = 0.0
    drained = 0
    while not engine.idle:
        t0 = time.perf_counter()
        engine.step()
        walls.append(1e3 * (time.perf_counter() - t0))
        drained += 1
        if drained > max_drain:
            raise RuntimeError("engine failed to drain the replayed load")
    return walls, len(engine.finished)


def run_latency(args) -> None:
    import jax

    from repro.configs import get_config
    from repro.models import init_lm, reduced
    from repro.serve.engine import PrefillProgram, cache_length
    from repro.serve.scheduler import ContinuousBatcher
    from repro.serve.slots import KVSlotManager, LMShard
    from repro.serve.traffic import make_traffic

    cfg = reduced(get_config("gemma-2b"))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    cache_len = cache_length(cfg, args.prompt_len + args.new_tokens + 2)

    def traffic():
        # same seed -> bit-identical arrivals for both engines (golden-
        # tested in tests/test_traffic.py); prompts are ragged in
        # [1, prompt_len] so admission cost varies per request
        return make_traffic("poisson", rate=args.rate,
                            prompt_len=args.prompt_len,
                            max_new_tokens=args.new_tokens,
                            vocab_size=cfg.vocab_size, seed=args.seed)

    batcher = ContinuousBatcher(params, cfg, slots=args.slots,
                                cache_len=cache_len)
    batcher.warmup()
    walls_b, fin_b = _replay(batcher, traffic(), args.steps)

    shard = LMShard(params, cfg, slots=args.slots, cache_len=cache_len)
    prefill = PrefillProgram(params, cfg, cache_len=cache_len)
    mgr = KVSlotManager([shard], prefill, cache_len=cache_len,
                        prefills_per_step=args.slots)
    mgr.warmup()
    # pre-trace the whole prefill ladder: a production engine compiles its
    # programs before taking traffic, and the A/B times serving, not XLA
    prefill.warmup(args.prompt_len)
    walls_d, fin_d = _replay(mgr, traffic(), args.steps)
    mgr.check()

    p95_b, p95_d = _pct(walls_b, 95), _pct(walls_d, 95)
    _emit("serve/requests_finished_batcher", fin_b,
          f"{len(walls_b)} steps incl. drain")
    _emit("serve/requests_finished_disagg", fin_d,
          f"{len(walls_d)} steps incl. drain; "
          f"prefill retraces={prefill.traces} of {prefill.calls} calls")
    _emit("serve/step_ms_p50_batcher", _pct(walls_b, 50),
          "PR-5 admission path: prefill token-by-token inside the step")
    _emit("serve/step_ms_p50_disagg", _pct(walls_d, 50),
          "disaggregated: bucketed prefill scan + handoff queue")
    _emit("serve/step_ms_p95_batcher", p95_b,
          "p95 lands on the steps that admit: P decode calls per prompt")
    _emit("serve/step_ms_p95_disagg", p95_d,
          "one fused scan per prompt, bounded prefills per step")
    _emit("serve/p95_ratio", p95_d / max(p95_b, 1e-12),
          "disagg / batcher whole-step p95 (<1 = disaggregation wins)")

    if args.steps < 30:
        _emit("serve/asserts", 0, "skipped (--steps < 30: no steady state)")
        return
    assert fin_b == fin_d > 0, (
        f"engines disagree on the replayed load: {fin_b} vs {fin_d}")
    assert p95_d < p95_b, (
        f"disaggregated p95 {p95_d:.3f}ms should beat the admission "
        f"path's {p95_b:.3f}ms on the same replayed arrivals")
    _emit("serve/asserts", 1, "same load, disaggregated p95 wins")


# ----------------------------------------------------------------- diurnal


def run_diurnal(args, mesh) -> None:
    from repro.api import (ClusterSpec, Experiment, MeshBackend, ServeSpec,
                           TrainConfig, paper_workload)
    from repro.optim import adam

    period = max(8, args.steps // 3)
    # near-zero trough + fast drain: the SLO policy's shrink arm demands
    # full idleness (occupancy 0) for idle_patience consecutive checks, so
    # the trough must actually empty the slots between peaks
    serve = ServeSpec(mode="dedicated", devices=1, engine="disaggregated",
                      traffic="diurnal", requests_per_round=0.05,
                      peak_rate=6.0, period=period, slots=args.slots,
                      decode_steps_per_round=4, prompt_len=args.prompt_len,
                      max_new_tokens=args.new_tokens,
                      slo_queue_delay=1.0, check_every=2, idle_patience=2)
    session = Experiment(
        workload=paper_workload("mnist-cnn"),
        cluster=ClusterSpec.homogeneous(
            30, args.workers, workload="mnist-cnn", seed=args.seed,
            backend=MeshBackend(mesh=mesh, concurrent=False), serve=serve),
        optimizer=adam(2e-3),
        config=TrainConfig(b0=args.b0, microbatch=args.b0 // 4,
                           batching="dynamic", init_allocation="uniform",
                           max_steps=args.steps, seed=args.seed),
    ).session()
    trainer = session.trainer

    losses, sums, extents = [], set(), []
    for rec in session:
        losses.append(rec.loss)
        sums.add(sum(rec.batches))
        extents.append(trainer.train_extent)
    trace = trainer.traffic.trace()
    if args.trace_csv:
        with open(args.trace_csv, "w") as fh:
            fh.write(trace.to_csv())
        print(f"# traffic trace -> {args.trace_csv}", file=sys.stderr)

    grows = [a for a in trainer.policy_log if a[1] == "grow"]
    shrinks = [a for a in trainer.policy_log if a[1] == "shrink"]
    # EWMA-smoothed like Session's stop criterion; target = halve the
    # opening loss within the run, under the serve region's oscillation
    smoothed = losses[0]
    for x in losses[1:]:
        smoothed = 0.1 * x + 0.9 * smoothed
    target = 0.5 * losses[0]

    _emit("serve/diurnal_rounds", len(losses),
          f"period={period} trough={serve.requests_per_round} "
          f"peak={serve.peak_rate}")
    _emit("serve/diurnal_arrivals", trace.total,
          f"seed={trace.seed} (trace replayable bit-identically)")
    _emit("serve/policy_grow_actions", len(grows),
          f"at steps {[s for s, _, _ in grows]}")
    _emit("serve/policy_shrink_actions", len(shrinks),
          f"at steps {[s for s, _, _ in shrinks]}")
    _emit("serve/train_extent_min", min(extents),
          f"max={max(extents)} of {trainer.data_extent} data-axis rows")
    _emit("serve/sum_bk_values", len(sums),
          f"distinct per-round Σb_k values: {sorted(sums)} (1 = conserved)")
    _emit("serve/loss_final_smoothed", smoothed,
          f"first={losses[0]:.4g} target={target:.4g}")
    st = trainer.serve_stats()
    _emit("serve/shards_final", st["shards"],
          f"slots_total={st['slots_total']} "
          f"slot_migrations={st['slot_migrations']} resumes={st['resumes']}")

    if args.steps < 30:
        _emit("serve/asserts", 0, "skipped (--steps < 30: no steady state)")
        return
    assert len(sums) == 1, f"global batch Σb_k drifted: {sorted(sums)}"
    assert grows and shrinks and len(trainer.policy_log) >= 2, (
        f"diurnal load must oscillate the device count: {trainer.policy_log}")
    assert smoothed <= target, (
        f"training failed to reach its loss target under oscillation: "
        f"{smoothed:.4g} > {target:.4g}")
    trainer.batcher.check()
    _emit("serve/asserts", 1,
          ">=2 policy oscillations + loss target + Σb_k conserved")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mode", default="latency",
                    choices=["latency", "diurnal"],
                    help="latency = admission-path vs disaggregated A/B on "
                         "a replayed Poisson load; diurnal = SLO-policy "
                         "oscillation under a diurnal envelope")
    ap.add_argument("--steps", type=int, default=60,
                    help="replay rounds (latency) / training rounds "
                         "(diurnal); assertions arm at >= 30")
    ap.add_argument("--devices", type=int, default=8,
                    help="fake CPU devices for the diurnal debug mesh")
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--b0", type=int, default=64)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots per engine (latency) / per shard")
    ap.add_argument("--rate", type=float, default=1.5,
                    help="Poisson arrivals per round (latency mode)")
    ap.add_argument("--prompt-len", type=int, default=24,
                    help="max ragged prompt length; admission cost scales "
                         "with it on the PR-5 path (P decode calls)")
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-csv", default=None,
                    help="write the diurnal arrival trace here (CSV)")
    ap.add_argument("--emit-json", default=None,
                    help="merge rows into the per-PR perf artifact, e.g. "
                         "BENCH_9.json (benchmarks/artifact.py)")
    args = ap.parse_args()

    _force_cpu_devices(args.devices)
    print("name,value,derived")
    if args.mode == "latency":
        run_latency(args)
    else:
        from repro.launch.mesh import make_debug_mesh

        run_diurnal(args, make_debug_mesh(args.devices))
    if args.emit_json:
        import jax

        from benchmarks.artifact import rows_to_payload, update_bench_json

        update_bench_json(
            args.emit_json, f"serve_bench/{args.mode}", {
                "steps": args.steps,
                "rows": rows_to_payload(_ROWS),
            },
            meta={"jax": jax.__version__, "devices": args.devices})


if __name__ == "__main__":
    main()
