"""Transient-VM scenario: one worker is preempted mid-run and later replaced
by a smaller spare; the controller re-balances both times (paper §II-A:
"omnivorous" training on spot/preemptible fleets).

    PYTHONPATH=src python examples/preemption_rebalance.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import ControllerConfig
from repro.het import WORKLOADS, ClusterSim, WorkerSpec, traces
from repro.models.simple import paper_workloads
from repro.optim import adam
from repro.train import HeterogeneousTrainer, TrainConfig


def main():
    wl = paper_workloads()["mnist-cnn"]

    def lag(params, batch, mask):
        def lf(p):
            ls, ws, aux = wl.loss_fn(p, batch, mask)
            return ls, (ls, ws, aux)  # SUM loss: trainer divides by w_sum

        (_, metas), g = jax.value_and_grad(lf, has_aux=True)(params)
        return metas, g

    counters = {}

    def nb(worker, n):
        counters[worker] = counters.get(worker, 0) + 1
        key = jax.random.fold_in(jax.random.PRNGKey(worker), counters[worker])
        return wl.make_batch(key, n)

    # worker 2: throttled to 30% capacity in [8s, 20s) (provider
    # overcommitment), then preempted-and-replaced by a half-size spare at
    # 20s (availability 0.5 thereafter)
    workers = [
        WorkerSpec(cores=8),
        WorkerSpec(cores=16),
        WorkerSpec(cores=24, trace=traces.compose(
            traces.step_interference(8.0, 20.0, 0.3),
            traces.step_interference(20.0, 1e9, 0.5))),
    ]
    sim = ClusterSim(workers, WORKLOADS["mnist-cnn"], seed=0)
    trainer = HeterogeneousTrainer(
        init_params=wl.init, loss_and_grad=lag, next_batch=nb,
        optimizer=adam(2e-3), sim=sim,
        cfg=TrainConfig(b0=32, microbatch=8, batching="dynamic",
                        max_steps=120,
                        controller=ControllerConfig(dead_band=0.05)))
    out = trainer.run()

    print("sim-time  batches            (adjustments marked)")
    last = None
    for rec in out["history"]:
        if rec.adjusted or last is None or rec.step == len(out["history"]) - 1:
            print(f"{rec.sim_time:7.1f}s  {rec.batches}"
                  f"{'   <- adjusted' if rec.adjusted else ''}")
        last = rec
    print(f"\nadjustments: {out['batch_adjustments']}, "
          f"final loss {out['final_loss']:.3f}")
    traj = [r.batches[2] for r in out["history"]]
    assert min(traj) < traj[0], "controller never shrank the throttled worker"
    print("controller shrank the throttled worker's batch "
          f"{traj[0]} -> {min(traj)} and re-balanced after replacement")


if __name__ == "__main__":
    main()
