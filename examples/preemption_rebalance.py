"""Transient-VM scenario (paper §II-A, "omnivorous" training on spot fleets):

  phase 1 — provider overcommitment throttles the big worker to 30%;
            the controller shrinks its batch (availability trace);
  phase 2 — the worker is PREEMPTED outright: a `RemoveWorker` event in the
            cluster schedule removes it, its batch share is reabsorbed by
            the survivors, and the surviving workers KEEP their controller
            state (EWMA windows, adaptive b_max, throughput history);
  phase 3 — a half-size spare joins (`AddWorker`): the schedule gives it a
            throughput-proportional slice and the controller re-equalizes.

    PYTHONPATH=src python examples/preemption_rebalance.py

The membership schedule is declarative data on the ClusterSpec — no
callback dict, no hand-driven loop.  Model state never restarts across
events (all-reduce data parallelism keeps full replicas); the engine
remaps its event queue in place.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import (
    AddWorker,
    ClusterSpec,
    Experiment,
    RemoveWorker,
    TrainConfig,
    paper_workload,
)
from repro.core import ControllerConfig
from repro.het import WorkerSpec, traces
from repro.optim import adam


def main():
    # worker 2: throttled to 30% capacity from sim-time 2s on (provider
    # overcommitment); preempted at step 50 and replaced at step 80
    cluster = ClusterSpec.explicit(
        [WorkerSpec(cores=8),
         WorkerSpec(cores=16),
         WorkerSpec(cores=24,
                    trace=traces.step_interference(2.0, 1e9, 0.3))],
        workload="mnist-cnn",
    ).with_schedule(
        RemoveWorker(step=50, worker=2),               # preemption
        AddWorker(step=80, spec=WorkerSpec(cores=12)),  # spare joins
    )
    experiment = Experiment(
        workload=paper_workload("mnist-cnn", seed=0),
        cluster=cluster,
        optimizer=adam(2e-3),
        config=TrainConfig(b0=32, microbatch=8, batching="dynamic",
                           max_steps=120,
                           controller=ControllerConfig(dead_band=0.05,
                                                       kind="gain")),
    )
    session = experiment.session()
    out = session.run()

    print("sim-time  batches            (adjustments marked)")
    for rec in out["history"]:
        if rec.adjusted or rec.step % 20 == 0 or rec.step in (50, 80):
            marks = []
            if rec.adjusted:
                marks.append("<- adjusted")
            if rec.step in (50, 80):
                marks.append("<- membership event")
            print(f"{rec.sim_time:7.1f}s  {rec.batches}   {' '.join(marks)}")
    controller = session.trainer.controller
    print(f"\nmembership log : {out['membership_log']}")
    print(f"adjustments    : {controller.num_updates}, "
          f"retunes: {controller.num_retunes}")
    print(f"final batches  : {out['final_batches']} "
          f"(global {sum(out['final_batches'])} preserved)")
    print(f"final loss     : {out['final_loss']:.3f}")

    traj2 = [r.batches[2] for r in out["history"] if len(r.batches) == 3
             and r.step < 50]
    assert min(traj2) < traj2[0], "controller never shrank the throttled worker"
    assert len(out["final_batches"]) == 3
    totals = {sum(r.batches) for r in out["history"]}
    assert totals == {sum(out["final_batches"])}, "global batch drifted"
    print("\nOK: throttled worker shrank "
          f"{traj2[0]} -> {min(traj2)}, share survived preemption, spare "
          "rebalanced without a restart")


if __name__ == "__main__":
    main()
