"""One Experiment, two execution backends, both sync modes (DESIGN.md
§11-§12).

The SAME declarative Experiment runs first under the calibrated cluster
simulator and then as ragged SPMD on a real JAX mesh: workers own disjoint
data-axis slices dispatched concurrently (max-of-workers BSP rounds, when
the axis is wide enough), per-worker batches are padded to a geometric
bucket ladder (bounded recompiles), padded rows are masked out of the
gradient, and the dynamic-batching controller closes its loop on MEASURED,
device-synced step times — with the cluster spec's declared heterogeneity
emulated through time dilation so both loops chase the same imbalance.
The last leg switches the mesh backend to ASP: the same event engine as
the simulator, fed measured per-worker completion times.

    PYTHONPATH=src python examples/mesh_train.py

CLI equivalents (the launcher accepts the same knobs):

    PYTHONPATH=src python -m repro.launch.train --backend mesh --steps 30
    PYTHONPATH=src python -m repro.launch.train --backend mesh --sync asp \\
        --steps 30                      # event-driven ASP on the mesh
    PYTHONPATH=src python -m repro.launch.train --backend mesh \\
        --ckpt /tmp/run.ckpt            # resumable via Session.restore
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import (ClusterSpec, Experiment, MeshBackend, TrainConfig,
                       paper_workload)
from repro.optim import sgd


def run_on(backend, label, sync="bsp"):
    experiment = Experiment(
        workload=paper_workload("linreg"),
        # 39 cores split (4, 11, 24) — heterogeneity level 6.  On the mesh
        # backend the core counts only shape the emulated time dilation.
        cluster=ClusterSpec.hlevel(39, 6, workload="mnist-cnn",
                                   backend=backend),
        optimizer=sgd(0.05),
        config=TrainConfig(b0=32, microbatch=8, batching="dynamic",
                           sync=sync, max_steps=60),
    )
    session = experiment.session()
    out = session.run()
    trainer = session.trainer
    print(f"[{label}]")
    print(f"  initial -> final batches : {out['history'][0].batches} -> "
          f"{out['final_batches']}")
    print(f"  batch adjustments        : {out['batch_adjustments']}")
    print(f"  recompiles (XLA traces)  : {trainer.accum_traces}")
    if hasattr(trainer, "worker_buckets"):
        print(f"  bucket rungs per worker  : "
              f"{[sorted(b) for b in trainer.worker_buckets]}")
    if getattr(trainer, "slice_plan", None) is not None:
        print(f"  data-axis slices         : "
              f"{list(trainer.slice_plan.slices)} (concurrent dispatch)")
    if sync == "asp":
        stale = [int(r.straggler_waste) for r in out["history"]]
        print(f"  update staleness         : mean "
              f"{sum(stale) / len(stale):.2f}, max {max(stale)}")
    print(f"  clock                    : {out['sim_time']:.3f}s "
          f"({'simulated' if backend is None else 'measured wall'})")
    return out


def main():
    run_on(None, "sim backend — modelled iteration times")
    out = run_on(MeshBackend(dilation="from-spec"),
                 "mesh backend — measured, ragged SPMD")
    assert out["steps"] == 60, "mesh run did not complete"
    out = run_on(MeshBackend(dilation="from-spec"),
                 "mesh backend, ASP — measured event-driven sync",
                 sync="asp")
    assert out["steps"] == 60, "mesh ASP run did not complete"


if __name__ == "__main__":
    main()
