"""Co-located serving + training on one mesh (DESIGN.md §13).

One Experiment, one 8-fake-device debug mesh, two tenants: the
dynamic-batching trainer owns the data axis, and a continuous-batching
decode loop shares the last worker's devices
(``ServeSpec(mode="shared")``).  Every BSP round the decode loop runs
first (serve-latency priority), its measured seconds are charged onto the
contended worker's step time, and the batch controller re-equalizes —
decode interference looks exactly like the paper's background-tenant
heterogeneity, so the contended worker's batch shrinks while round times
stay equal.

    PYTHONPATH=src python examples/colocated.py [--steps 40]

CLI equivalent (any mesh, same knobs):

    PYTHONPATH=src python -m repro.launch.train --backend mesh --serve \\
        --serve-mode shared --steps 40

The dedicated-slice variant with the SLO grow/shrink policy is exercised
by ``benchmarks/colocate_bench.py --mode policy``.
"""

import argparse
import os
import sys

# fake devices must land in XLA_FLAGS before jax initializes
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        f"{_FLAG}=8 {os.environ.get('XLA_FLAGS', '')}".strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import (ClusterSpec, Experiment, MeshBackend, ServeSpec,
                       TrainConfig)
from repro.api import paper_workload
from repro.core import ControllerConfig
from repro.launch.mesh import make_debug_mesh
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    mesh = make_debug_mesh(8)   # data axis = 4 -> one device per worker + 1
    experiment = Experiment(
        workload=paper_workload("mnist-cnn"),
        # homogeneous fleet + uniform initial batches: ALL heterogeneity
        # the controller sees comes from the decode traffic on the
        # contended worker's slice
        # concurrent=False: fake devices share a couple of host cores, so
        # only sequential dispatch gives per-worker times proportional to
        # batch size (see benchmarks/README.md on the debug-mesh caveat);
        # on real disjoint hardware drop the flag
        cluster=ClusterSpec.homogeneous(
            30, 3,
            backend=MeshBackend(mesh=mesh, concurrent=False),
            serve=ServeSpec(mode="shared", requests_per_round=0.5,
                            slots=2, decode_steps_per_round=2,
                            prompt_len=2, max_new_tokens=4)),
        optimizer=adam(2e-3),
        # adaptive_bmax off: the throughput guard reacts to clean simulated
        # memory cliffs; measured-time noise at toy scale would false-
        # trigger it and freeze the plan (DESIGN.md §13)
        config=TrainConfig(b0=128, microbatch=32, batching="dynamic",
                           init_allocation="uniform", max_steps=args.steps,
                           controller=ControllerConfig(adaptive_bmax=False)),
    )
    session = experiment.session()
    out = session.run()
    trainer = session.trainer

    contended = trainer.serve_slice.shared_with
    first, last = out["history"][0], out["history"][-1]
    serve = out["serve"]
    print(f"serve slice              : devices "
          f"{list(trainer.serve_slice.devices())} "
          f"(time-multiplexed with worker {contended})")
    print(f"batches first -> last    : {first.batches} -> {last.batches}")
    print(f"requests finished/queued : {serve['requests_finished']}/"
          f"{serve['requests_queued']}")
    print(f"decode step ms p50/p95   : {serve['decode_step_ms']['p50']:.2f}/"
          f"{serve['decode_step_ms']['p95']:.2f}")
    print(f"queue delay steps (mean) : "
          f"{serve['queue_delay_steps']['mean']:.2f}")
    print(f"interference charged     : {serve['charged_seconds']:.3f}s "
          f"onto worker {contended}")
    assert out["steps"] == args.steps, "co-located run did not complete"
    assert serve["decode_steps"] > 0, "decode loop never ran"
    assert serve["charged_seconds"] > 0, "no interference was charged"
    if args.steps >= 30:
        # the contended worker's controller-chosen batch dropped; the
        # strict 10% equal-time invariant is benchmarks/colocate_bench.py's
        # job — it runs much longer with a queue-saturated (steady) decode
        # load, while this demo's light bursty traffic shows the mechanism
        # rather than a converged equilibrium
        assert last.batches[contended] < first.batches[contended], (
            f"contended worker batch should drop: "
            f"{first.batches} -> {last.batches}")
    print("OK")


if __name__ == "__main__":
    main()
