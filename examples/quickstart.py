"""Quickstart: the dynamic batching controller in 60 lines.

Three simulated heterogeneous workers train a linear-regression model; the
controller discovers throughput-proportional batch sizes online (paper
Fig. 4a) and cuts the iteration-time gap.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import DynamicBatchController
from repro.het import WORKLOADS, ClusterSim, hlevel_cluster
from repro.models.simple import paper_workloads
from repro.optim import sgd
from repro.train import HeterogeneousTrainer, TrainConfig


def main():
    wl = paper_workloads()["linreg"]

    def loss_and_grad(params, batch, mask):
        def lf(p):
            ls, ws, aux = wl.loss_fn(p, batch, mask)
            return ls, (ls, ws, aux)  # SUM loss: trainer divides by w_sum

        (_, metas), g = jax.value_and_grad(lf, has_aux=True)(params)
        return metas, g

    counters = {}

    def next_batch(worker, n):
        counters[worker] = counters.get(worker, 0) + 1
        key = jax.random.fold_in(jax.random.PRNGKey(worker), counters[worker])
        return wl.make_batch(key, n)

    # a 39-core cluster split (4, 11, 24) — heterogeneity level 6
    sim = ClusterSim(hlevel_cluster(39, 6), WORKLOADS["mnist-cnn"], seed=0)
    trainer = HeterogeneousTrainer(
        init_params=wl.init,
        loss_and_grad=loss_and_grad,
        next_batch=next_batch,
        optimizer=sgd(0.05),
        sim=sim,
        cfg=TrainConfig(b0=32, microbatch=8, batching="dynamic",
                        max_steps=150, target_loss=0.02),
    )
    out = trainer.run()

    print(f"worker cores      : {[w.cores for w in sim.workers]}")
    print(f"initial batches   : {out['history'][0].batches}")
    print(f"converged batches : {out['final_batches']}  "
          f"(throughput-proportional)")
    print(f"batch adjustments : {out['batch_adjustments']}")
    print(f"steps/sim-time    : {out['steps']} / {out['sim_time']:.1f}s")
    print(f"final loss        : {out['final_loss']:.4f}")
    assert out["reached_target"], "did not reach target loss"


if __name__ == "__main__":
    main()
