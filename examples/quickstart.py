"""Quickstart: the canonical new-API demo, ~20 lines of wiring.

Three simulated heterogeneous workers train a linear-regression model; the
dynamic-batching controller discovers throughput-proportional batch sizes
online (paper Fig. 4a) and cuts the iteration-time gap.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import ClusterSpec, Experiment, TrainConfig, paper_workload
from repro.optim import sgd


def main():
    experiment = Experiment(
        workload=paper_workload("linreg"),
        # a 39-core cluster split (4, 11, 24) — heterogeneity level 6;
        # iteration times follow the mnist-cnn cost model
        cluster=ClusterSpec.hlevel(39, 6, workload="mnist-cnn"),
        optimizer=sgd(0.05),
        config=TrainConfig(b0=32, microbatch=8, batching="dynamic",
                           max_steps=150, target_loss=0.02),
    )
    out = experiment.run()

    print(f"worker cores      : {[w.cores for w in experiment.cluster.workers]}")
    print(f"initial batches   : {out['history'][0].batches}")
    print(f"converged batches : {out['final_batches']}  "
          f"(throughput-proportional)")
    print(f"batch adjustments : {out['batch_adjustments']}")
    print(f"steps/sim-time    : {out['steps']} / {out['sim_time']:.1f}s")
    print(f"final loss        : {out['final_loss']:.4f}")
    assert out["reached_target"], "did not reach target loss"


if __name__ == "__main__":
    main()
