"""Serving example: batched-request KV-cache decoding on a small LM.

    PYTHONPATH=src python examples/serve_batched.py

Loads a reduced gemma config, prefilloads a batch of prompts, decodes with
the shared serve engine (same serve_step the decode dry-run shapes lower),
and verifies greedy decode is deterministic.
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_lm, reduced
from repro.serve import ServeConfig, generate


def main():
    cfg = reduced(get_config("gemma-2b"))
    params = init_lm(jax.random.PRNGKey(0), cfg)

    batch, prompt_len, gen_len = 4, 12, 16
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                 0, cfg.vocab_size)

    t0 = time.perf_counter()
    out1 = generate(params, cfg, prompts, gen_len,
                    ServeConfig(max_seq=prompt_len + gen_len))
    t1 = time.perf_counter()
    out2 = generate(params, cfg, prompts, gen_len,
                    ServeConfig(max_seq=prompt_len + gen_len))

    print(f"prompts       : {prompts.shape}")
    print(f"generated     : {out1.shape} in {t1-t0:.2f}s "
          f"(incl. compile)")
    print(f"deterministic : {bool(jnp.array_equal(out1, out2))}")
    print(f"sample tokens : {out1[0][:8].tolist()}")
    assert jnp.array_equal(out1, out2)
    # temperature sampling path (untrained logits are sharp, so the sampled
    # sequence may coincide with greedy — determinism is what we assert)
    out3 = generate(params, cfg, prompts, gen_len,
                    ServeConfig(max_seq=prompt_len + gen_len,
                                temperature=5.0))
    print(f"sampled(T=5) != greedy: {not bool(jnp.array_equal(out1, out3))}")


if __name__ == "__main__":
    main()
