"""End-to-end driver: train a ~100M-param transformer for a few hundred steps
on a heterogeneous 3-worker cluster, uniform vs dynamic batching, with a
mid-run interference spike that the controller adapts to.

    PYTHONPATH=src python examples/heterogeneous_train.py [--steps 200]

This is the deliverable-(b) end-to-end example: real SGD on a real LM
(llama-family, ~100M params), real data pipeline (Markov-mixture stream),
checkpointing, and the paper's controller in the loop. Wall-clock comes
from the calibrated cluster simulator (DESIGN.md §2: CPU-only container).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core import ControllerConfig
from repro.data import DataPipeline
from repro.het import WORKLOADS, ClusterSim, hlevel_cluster, traces
from repro.models import init_lm, lm_loss
from repro.optim import adam
from repro.train import HeterogeneousTrainer, TrainConfig


def build(steps: int, batching: str, seed: int = 0, controller: str = "p"):
    # ~100M-param llama-family config (deliverable (b): train ~100M model)
    cfg = get_config("llama3-8b").with_(
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1408, vocab_size=8192)
    seq_len = 128

    pipe = DataPipeline(cfg, seq_len=seq_len, num_workers=3, seed=seed)

    def loss_and_grad(params, batch, mask):
        def lf(p):
            ls, ws, aux = lm_loss(p, cfg, batch["tokens"], batch["targets"],
                                  mask)
            return ls, (ls, ws, aux)  # SUM loss: trainer divides by w_sum

        (_, metas), g = jax.value_and_grad(lf, has_aux=True)(params)
        return metas, g

    workers = hlevel_cluster(39, 6)
    # interference hits the largest worker mid-run
    workers[-1].trace = traces.step_interference(200.0, 1e9, 0.35)
    sim = ClusterSim(workers, WORKLOADS["transformer"], seed=seed)

    trainer = HeterogeneousTrainer(
        init_params=lambda k: init_lm(k, cfg),
        loss_and_grad=loss_and_grad,
        next_batch=pipe.next_batch,
        optimizer=adam(3e-4),
        sim=sim,
        cfg=TrainConfig(b0=8, microbatch=4, batching=batching,
                        max_steps=steps, seed=seed,
                        controller=ControllerConfig(dead_band=0.05,
                                                    kind=controller)),
    )
    return cfg, pipe, trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--controller", default="p",
                    choices=["p", "pi", "pid", "gain"],
                    help="control law for the dynamic mode (the 'gain' and "
                         "'pid' variants recover from the interference step "
                         "in fewer readjustments than the paper's P law)")
    ap.add_argument("--ckpt", default="/tmp/het_train.npz")
    args = ap.parse_args()

    results = {}
    for mode in ("uniform", "dynamic"):
        cfg, pipe, trainer = build(args.steps, mode, controller=args.controller)
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(
            trainer.params))
        out = trainer.run()
        results[mode] = out
        print(f"\n=== {mode} batching ({n_params/1e6:.0f}M params) ===")
        for rec in out["history"][:: max(1, args.steps // 8)]:
            print(f"  step {rec.step:4d} sim_t={rec.sim_time:8.1f}s "
                  f"loss={rec.loss:6.3f} batches={rec.batches}"
                  f"{'  <- adjusted' if rec.adjusted else ''}")
        print(f"  total sim time  : {out['sim_time']:.1f}s")
        print(f"  final loss      : {out['final_loss']:.3f}")
        print(f"  adjustments     : {out['batch_adjustments']}")
        if mode == "dynamic":
            save_checkpoint(args.ckpt,
                            {"params": trainer.params},
                            {"controller": trainer.controller.state_dict(),
                             "data": pipe.state_dict(),
                             "steps": out["steps"]})
            _, meta = load_checkpoint(args.ckpt)
            print(f"  checkpoint ok   : {args.ckpt} "
                  f"(controller batches {meta['controller']['workers']})")

    speedup = results["uniform"]["sim_time"] / results["dynamic"]["sim_time"]
    print(f"\nDynamic batching speedup at same step count: {speedup:.2f}x")


if __name__ == "__main__":
    main()
