"""End-to-end driver: train a ~100M-param transformer for a few hundred steps
on a heterogeneous 3-worker cluster, uniform vs dynamic batching, with a
mid-run interference spike that the controller adapts to.

    PYTHONPATH=src python examples/heterogeneous_train.py [--steps 200]

This is the deliverable-(b) end-to-end example: real SGD on a real LM
(llama-family, ~100M params), real data pipeline (Markov-mixture stream),
checkpointing through the Session, and the paper's controller in the loop.
Wall-clock comes from the calibrated cluster simulator (DESIGN.md §2:
CPU-only container); all wiring goes through `repro.api` (DESIGN.md §10).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.api import ClusterSpec, Experiment, TrainConfig, lm_workload
from repro.checkpoint import load_checkpoint
from repro.configs import get_config
from repro.core import ControllerConfig
from repro.data import DataPipeline
from repro.het import traces
from repro.optim import adam


def build(steps: int, batching: str, seed: int = 0, controller: str = "p"):
    # ~100M-param llama-family config (deliverable (b): train ~100M model)
    cfg = get_config("llama3-8b").with_(
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1408, vocab_size=8192)
    pipe = DataPipeline(cfg, seq_len=128, num_workers=3, seed=seed)

    experiment = Experiment(
        workload=lm_workload(cfg, pipe),
        # interference hits the largest worker mid-run
        cluster=ClusterSpec.hlevel(39, 6, workload="transformer", seed=seed)
            .with_trace(-1, traces.step_interference(200.0, 1e9, 0.35)),
        optimizer=adam(3e-4),
        config=TrainConfig(b0=8, microbatch=4, batching=batching,
                           max_steps=steps, seed=seed,
                           controller=ControllerConfig(dead_band=0.05,
                                                       kind=controller)),
    )
    return cfg, experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--controller", default="p",
                    choices=["p", "pi", "pid", "gain"],
                    help="control law for the dynamic mode (the 'gain' and "
                         "'pid' variants recover from the interference step "
                         "in fewer readjustments than the paper's P law)")
    ap.add_argument("--ckpt", default="/tmp/het_train.npz")
    args = ap.parse_args()

    results = {}
    for mode in ("uniform", "dynamic"):
        cfg, experiment = build(args.steps, mode, controller=args.controller)
        session = experiment.session()
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(
            session.params))
        out = session.run()
        results[mode] = out
        print(f"\n=== {mode} batching ({n_params/1e6:.0f}M params) ===")
        for rec in out["history"][:: max(1, args.steps // 8)]:
            print(f"  step {rec.step:4d} sim_t={rec.sim_time:8.1f}s "
                  f"loss={rec.loss:6.3f} batches={rec.batches}"
                  f"{'  <- adjusted' if rec.adjusted else ''}")
        print(f"  total sim time  : {out['sim_time']:.1f}s")
        print(f"  final loss      : {out['final_loss']:.3f}")
        print(f"  adjustments     : {out['batch_adjustments']}")
        if mode == "dynamic":
            session.save(args.ckpt, extra_meta={"arch": "llama3-8b@100M"})
            _, meta = load_checkpoint(args.ckpt)
            ctrl_batches = [w["batch"]
                            for w in meta["session"]["controller"]["workers"]]
            print(f"  checkpoint ok   : {args.ckpt} "
                  f"(controller batches {ctrl_batches})")

    speedup = results["uniform"]["sim_time"] / results["dynamic"]["sim_time"]
    print(f"\nDynamic batching speedup at same step count: {speedup:.2f}x")


if __name__ == "__main__":
    main()
